"""Mamba2 SSD (state-space duality, arXiv:2405.21060) — chunked dual form.

The sequence is split into chunks of Q tokens.  Within a chunk the recurrence
is unrolled into an attention-like lower-triangular matmul (MXU work); across
chunks only the (H, P, N) state is carried — O(1) per chunk — so the whole
layer is sub-quadratic in S and dominated by dense matmuls.  Decode uses the
exact recurrent form on a persistent state.

Shapes: x (B, S, H, P) heads of the expanded inner dim; B/C (B, S, N) one
shared group; dt (B, S, H) softplus-positive step sizes; A (H,) negative.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .scan_util import scan as _scan

CHUNK = 128


def segsum(log_a):
    """(..., Q) per-step log decay -> (..., Q, Q) lower-tri pairwise sums:
    out[t, s] = sum_{r in (s, t]} log_a[r] for s < t (else -inf off-tri)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # l_t - l_s
    tri = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(tri, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bmat, Cmat, h0=None, chunk: int = CHUNK):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) (negative); Bmat/Cmat: (B, S, N).
    h0: optional initial state (B, H, P, N).  Returns (y (B,S,H,P),
    h_final (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bmat.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cmat.reshape(Bsz, nc, chunk, N).astype(f32)
    log_a = dtc * A.astype(f32)[None, None, None, :]     # (B,nc,Q,H) <= 0
    log_a = log_a.transpose(0, 1, 3, 2)                  # (B,nc,H,Q)
    xdt = xc * dtc[..., None]                            # dt-scaled input

    # ---- intra-chunk (dual/attention-like) ----
    Lmat = jnp.exp(segsum(log_a))                        # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)       # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, Lmat, xdt)

    # ---- chunk summary states ----
    csum = jnp.cumsum(log_a, axis=-1)                    # (B,nc,H,Q)
    total = csum[..., -1:]                               # (B,nc,H,1)
    decay_to_end = jnp.exp(total - csum)                 # exp(sum_{r>s} log_a)
    states = jnp.einsum("bckn,bchk,bckhp->bchpn", Bc, decay_to_end, xdt)

    # ---- inter-chunk state carry (sequential scan over chunks) ----
    chunk_decay = jnp.exp(total[..., 0])                 # (B,nc,H)

    def carry(h, inp):
        st, dec = inp                                    # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    h_init = jnp.zeros((Bsz, H, P, N), f32) if h0 is None else h0.astype(f32)
    h_last, h_prevs = _scan(
        carry, h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,P,N)

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(csum)                     # exp(sum_{r<=t} log_a)
    y_inter = jnp.einsum("bcqn,bchq,bchpn->bcqhp",
                         Cc, decay_from_start, h_prevs)

    y = (y_intra + y_inter).reshape(Bsz, nc * chunk, H, P)[:, :S]
    return y.astype(x.dtype), h_last


def ssd_decode_step(x, dt, A, Bvec, Cvec, h):
    """Recurrent single step.  x: (B,H,P); dt: (B,H); B/C: (B,N);
    h: (B,H,P,N).  Returns (y (B,H,P), h')."""
    f32 = jnp.float32
    a = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])         # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", (x * dt[..., None]).astype(f32),
                     Bvec.astype(f32))
    h_new = h * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cvec.astype(f32))
    return y.astype(x.dtype), h_new


def causal_conv(x, w, cache=None):
    """Depthwise causal conv1d.  x: (B, S, Cch); w: (K, Cch).
    With cache (B, K-1, Cch): single-step update (S == 1)."""
    K = w.shape[0]
    if cache is not None:
        window = jnp.concatenate([cache, x], axis=1)     # (B, K, C)
        y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None]
        return y.astype(x.dtype), window[:, 1:]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]].astype(jnp.float32)
            * w[i].astype(jnp.float32) for i in range(K))
    return y.astype(x.dtype), xp[:, -(K - 1):] if K > 1 else None
