"""Mixture-of-Experts: token-choice top-k routing with capacity.

Dispatch is scatter/gather based (not dense one-hot einsum) so compiled HLO
FLOPs stay ~= active-expert FLOPs * capacity_factor — the roofline's
MODEL_FLOPS/HLO_FLOPs ratio stays honest (a dense all-experts dispatch would
inflate HLO FLOPs by E/top_k).

Per expert e the slots are filled first-come-first-served (cumsum position);
overflow tokens are dropped (their combine weight contribution is zero),
which is the standard capacity-factor trade-off at scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard


def moe_ffn(x, w_router, w_gate, w_in, w_out, *, top_k: int,
            capacity_factor: float, dropless: bool = False,
            groups: int = 0):
    """x: (B, S, d); expert weights: (E, d, ff) / (E, ff, d).

    Returns (B, S, d).  Capacity C = ceil(cf * T * top_k / E) with
    T = B * S (static), so the dispatch buffers have static shapes.

    ``dropless=True`` sets C = T (no token ever dropped) — used by the
    single-token decode path where T = batch is small; full-sequence paths
    keep capacity routing, whose batch-coupled drops are the standard
    GShard/Switch approximation (noted in DESIGN.md §6).
    """
    B, S, d = x.shape
    E = w_gate.shape[0]
    if groups:
        return _grouped_moe_ffn(x, w_router, w_gate, w_in, w_out,
                                top_k=top_k, capacity_factor=capacity_factor,
                                groups=groups)
    T = B * S
    C = T if dropless else max(1, int(capacity_factor * T * top_k / E))
    xf = x.reshape(T, d)
    logits = (xf @ w_router).astype(jnp.float32)            # (T, E)
    top_vals, top_idx = jax.lax.top_k(logits, top_k)        # (T, k)
    gates = jax.nn.softmax(top_vals, axis=-1).astype(x.dtype)

    y = jnp.zeros((T, d), x.dtype)
    token_ids = jnp.arange(T, dtype=jnp.int32)
    for j in range(top_k):                                  # k <= 2, unrolled
        e = top_idx[:, j]                                   # (T,)
        onehot = (e[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot           # 1-indexed slot
        slot = pos.sum(axis=1) - 1                          # (T,)
        keep = slot < C
        flat = jnp.where(keep, e * C + slot, E * C)         # E*C = drop bin
        # token index per (expert, slot)
        owner = jnp.full((E * C + 1,), T, jnp.int32).at[flat].set(
            token_ids, mode="drop")[: E * C]
        xg = jnp.where((owner < T)[:, None],
                       xf[jnp.clip(owner, 0, T - 1)], 0).reshape(E, C, d)
        # capacity dim sharded over the batch (DP) axes: dispatch buffers
        # stay O(T/dp) per device even when E doesn't divide the model axis
        xg = shard(xg, "experts", "batch", "embed")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, w_gate)) * \
            jnp.einsum("ecd,edf->ecf", xg, w_in)
        h = shard(h, "experts", "batch", "expert_ff")
        out = jnp.einsum("ecf,efd->ecd", h, w_out).reshape(E * C, d)
        contrib = jnp.zeros((T + 1, d), x.dtype).at[owner].add(
            out, mode="drop")[:T]
        y = y + contrib * gates[:, j:j + 1]
    return y.reshape(B, S, d)


def _grouped_moe_ffn(x, w_router, w_gate, w_in, w_out, *, top_k: int,
                     capacity_factor: float, groups: int):
    """Hierarchical dispatch (EXPERIMENTS §Perf H1b): tokens are routed in
    ``groups`` independent blocks whose leading dim is sharded over the DP
    axes, so the dispatch gather/scatter is LOCAL per data shard — the
    measured alternative global dispatch materializes (E*C, d) cross-shard
    gathers that GSPMD lowers to multi-GB all-reduces (grok baseline).
    Capacity is per group (C_g = cf*T_g*k/E), the same total budget."""
    B, S, d = x.shape
    E = w_gate.shape[0]
    G = groups
    T = B * S
    assert T % G == 0, (T, G)
    Tg = T // G
    C = max(1, int(capacity_factor * Tg * top_k / E))
    xf = shard(x.reshape(G, Tg, d), "batch", None, "embed")
    logits = jnp.einsum("gtd,de->gte", xf, w_router).astype(jnp.float32)
    top_vals, top_idx = jax.lax.top_k(logits, top_k)        # (G, Tg, k)
    gates = jax.nn.softmax(top_vals, axis=-1).astype(x.dtype)
    token_ids = jnp.arange(Tg, dtype=jnp.int32)
    rows = jnp.arange(G, dtype=jnp.int32)[:, None]
    y = jnp.zeros((G, Tg, d), x.dtype)
    for j in range(top_k):
        e = top_idx[..., j]                                  # (G, Tg)
        onehot = (e[..., None] == jnp.arange(E)[None, None, :]).astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=1) * onehot
        slot = pos.sum(axis=2) - 1                           # (G, Tg)
        keep = slot < C
        flat = jnp.where(keep, e * C + slot, E * C)
        owner = jnp.full((G, E * C + 1), Tg, jnp.int32).at[
            rows, flat].set(jnp.broadcast_to(token_ids, (G, Tg)),
                            mode="drop")[:, :E * C]
        xg = jnp.take_along_axis(
            xf, jnp.clip(owner, 0, Tg - 1)[..., None], axis=1)
        xg = jnp.where((owner < Tg)[..., None], xg, 0).reshape(G, E, C, d)
        xg = shard(xg, "batch", "experts", None, "embed")
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xg, w_gate)) * \
            jnp.einsum("gecd,edf->gecf", xg, w_in)
        h = shard(h, "batch", "experts", None, "expert_ff")
        out = jnp.einsum("gecf,efd->gecd", h, w_out).reshape(G, E * C, d)
        contrib = jnp.zeros((G, Tg + 1, d), x.dtype).at[
            rows, jnp.where(owner < Tg, owner, Tg)].add(out)[:, :Tg]
        y = y + contrib * gates[..., j][..., None]   # token-indexed combine
    return y.reshape(B, S, d)


def init_moe(pb, tree, specs, prefix, cfg):
    """Stacked per-layer MoE weights: (L, E, d, ff).

    moe_contraction_fsdp lays experts out (E, d/data, ff/model) so the
    per-layer FSDP gather moves only the data-sharded contraction slices
    (TP shard stays resident) — EXPERIMENTS §Perf hillclimb H1."""
    L, E, d, ff = cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_ff
    d_ax = "embed_fsdp" if cfg.moe_contraction_fsdp else "embed"
    ff_ax = "expert_ff_tp" if cfg.moe_contraction_fsdp else "expert_ff"
    pb.normal(tree, specs, f"{prefix}router", (L, d, E),
              (None, "embed", "experts"))
    pb.normal(tree, specs, f"{prefix}gate", (L, E, d, ff),
              (None, "experts", d_ax, ff_ax))
    pb.normal(tree, specs, f"{prefix}in", (L, E, d, ff),
              (None, "experts", d_ax, ff_ax))
    pb.normal(tree, specs, f"{prefix}out", (L, E, ff, d),
              (None, "experts", ff_ax, d_ax))
