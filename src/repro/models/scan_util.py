"""Scan with a global cost-mode switch.

XLA's ``cost_analysis`` counts a while-loop body ONCE (verified by probe, see
EXPERIMENTS.md §Dry-run "costing methodology"), so the dry-run costing pass
re-lowers the step with every ``lax.scan`` fully unrolled at a reduced layer
count and extrapolates.  All model scans route through :func:`scan` so the
switch is one context manager.
"""

from __future__ import annotations

import contextlib
import threading

import jax


class _Flag(threading.local):
    def __init__(self):
        self.unroll = False
        self.vma_axes: tuple = ()


_FLAG = _Flag()


@contextlib.contextmanager
def cost_mode():
    """Unroll every model scan (dry-run costing pass only)."""
    prev = _FLAG.unroll
    _FLAG.unroll = True
    try:
        yield
    finally:
        _FLAG.unroll = prev


@contextlib.contextmanager
def vma_axes(axes: tuple):
    """Mark model-scan carries as varying over manual shard_map axes.

    Used by the cross-pod compressed train step (partial-manual shard_map
    with check_vma): scan carries initialized from invariant zeros must be
    pcast to 'varying' because the scanned inputs derive from the pod-local
    batch.  A no-op outside this context."""
    prev = _FLAG.vma_axes
    _FLAG.vma_axes = tuple(axes)
    try:
        yield
    finally:
        _FLAG.vma_axes = prev


def pvary(tree):
    """pcast a pytree to 'varying' over the active vma axes (no-op default;
    leaves that are already varying are left untouched).  On jax versions
    without value-type checking (no ``jax.lax.pcast``, e.g. 0.4.x) this is a
    no-op: the cross-pod step runs shard_map with ``check_rep=False`` there,
    so no variance proof is required (see runtime.train)."""
    if not _FLAG.vma_axes or not hasattr(jax.lax, "pcast"):
        return tree

    def one(a):
        try:
            return jax.lax.pcast(a, _FLAG.vma_axes, to="varying")
        except ValueError:   # already varying over (a superset of) the axes
            return a

    return jax.tree.map(one, tree)


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, pvary(init), xs, length=length,
                        unroll=True if _FLAG.unroll else 1)
