"""Unified LM builder covering all 10 assigned architecture families.

Design notes (DESIGN.md §6-7):
  * pure-functional: params are nested dicts of stacked per-layer arrays,
    the layer stack is a single ``lax.scan`` (HLO size stays flat in depth;
    remat policy per config wraps the scanned body);
  * families compose from the same primitives: dense/vlm/audio = attention +
    SwiGLU; moe swaps the FFN; ssm = Mamba2 SSD blocks; hybrid = parallel
    attention+SSM paths (Hymba) + SwiGLU; encdec = encoder stack + decoder
    with cross-attention (Seamless text decoder, audio frontend stubbed);
  * serving: ``prefill`` builds a KV/SSM cache, ``decode_step`` advances one
    token.  Sliding-window archs use ring caches (masking by absolute
    position); ``kv_cache_dtype='int8'`` block-quantizes the cache (needed
    for qwen1.5-32b MHA at decode_32k on 16 GB chips);
  * every tensor is annotated with logical axes -> the sharding resolver
    (repro.parallel.sharding) turns them into mesh shardings.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.parallel.sharding import shard
from . import attention as attn_lib
from .scan_util import scan as _scan
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import ParamBuilder, cross_entropy, head_rms_norm, rms_norm, rope, swiglu

IGNORE = -100


def _remat(fn, policy: str):
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "full":
        return jax.checkpoint(fn)
    return fn


class LM:
    """Builds/executes one architecture.  All methods are jit-compatible."""

    def __init__(self, cfg: ArchConfig, param_dtype=jnp.bfloat16,
                 kv_cache_dtype: Optional[str] = None):
        self.cfg = cfg
        self.param_dtype = param_dtype
        self.kv_cache_dtype = kv_cache_dtype or (
            "int8" if cfg.name.startswith("qwen15_32b") else "bf16")
        self._specs: Optional[dict] = None

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def init(self, rng: jax.Array):
        cfg = self.cfg
        pb = ParamBuilder(rng, self.param_dtype)
        p, s = {}, {}
        pb.normal(p, s, "embed", (cfg.padded_vocab, cfg.d_model),
                  ("vocab", "embed"), scale=0.02)
        if cfg.meta_tokens:
            pb.normal(p, s, "meta", (cfg.meta_tokens, cfg.d_model),
                      (None, "embed"), scale=0.02)
        p["layers"], s["layers"] = self._init_stack(pb, cfg.n_layers, cfg,
                                                    decoder=True)
        if cfg.is_encdec:
            p["enc_layers"], s["enc_layers"] = self._init_stack(
                pb, cfg.enc_layers, cfg, decoder=False)
            pb.ones(p, s, "enc_final_norm", (cfg.d_model,), ("embed",))
        pb.ones(p, s, "final_norm", (cfg.d_model,), ("embed",))
        self._specs = s
        return p

    def param_specs(self):
        if self._specs is None:
            # build shapes abstractly to obtain the specs tree
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._specs

    def _init_stack(self, pb, L, cfg, *, decoder: bool):
        p, s = {}, {}
        d, hd = cfg.d_model, cfg.head_dim
        H, KV = cfg.n_heads, cfg.n_kv_heads
        has_attn = cfg.family != "ssm"
        has_ssm = cfg.family in ("ssm", "hybrid")
        if has_attn:
            pb.ones(p, s, "ln_attn", (L, d), (None, "embed"))
            pb.normal(p, s, "wq", (L, d, H * hd), (None, "embed", "heads"))
            pb.normal(p, s, "wk", (L, d, KV * hd), (None, "embed", "kv_heads"))
            pb.normal(p, s, "wv", (L, d, KV * hd), (None, "embed", "kv_heads"))
            pb.normal(p, s, "wo", (L, H * hd, d), (None, "heads", "embed"))
            if cfg.qkv_bias:
                pb.zeros(p, s, "bq", (L, H * hd), (None, "heads"))
                pb.zeros(p, s, "bk", (L, KV * hd), (None, "kv_heads"))
                pb.zeros(p, s, "bv", (L, KV * hd), (None, "kv_heads"))
            if cfg.qk_norm:
                pb.ones(p, s, "q_norm", (L, hd), (None, "head_dim"))
                pb.ones(p, s, "k_norm", (L, hd), (None, "head_dim"))
            if decoder and cfg.is_encdec:
                pb.ones(p, s, "ln_cross", (L, d), (None, "embed"))
                pb.normal(p, s, "cwq", (L, d, H * hd), (None, "embed", "heads"))
                pb.normal(p, s, "cwk", (L, d, KV * hd), (None, "embed", "kv_heads"))
                pb.normal(p, s, "cwv", (L, d, KV * hd), (None, "embed", "kv_heads"))
                pb.normal(p, s, "cwo", (L, H * hd, d), (None, "heads", "embed"))
        if has_ssm:
            di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            K = cfg.ssm_conv
            pb.ones(p, s, "ln_ssm", (L, d), (None, "embed"))
            if cfg.ssm_split_proj:
                # TP-clean variant: sharded z/x, replicated B/C, head-sharded
                # dt — no sharded-dim splits, no per-layer reshard collectives
                pb.normal(p, s, "ssm_wz", (L, d, di), (None, "embed", "ssm_inner"))
                pb.normal(p, s, "ssm_wx", (L, d, di), (None, "embed", "ssm_inner"))
                pb.normal(p, s, "ssm_wbc", (L, d, 2 * N), (None, "embed", None))
                pb.normal(p, s, "ssm_wdt", (L, d, Hs), (None, "embed", "ssm_heads"))
                pb.normal(p, s, "conv_x_w", (L, K, di), (None, None, "ssm_inner"),
                          scale=0.5)
                pb.normal(p, s, "conv_bc_w", (L, K, 2 * N), (None, None, None),
                          scale=0.5)
            else:
                pb.normal(p, s, "ssm_in", (L, d, 2 * di + 2 * N + Hs),
                          (None, "embed", "ssm_inner"))
                pb.normal(p, s, "conv_w", (L, K, di + 2 * N),
                          (None, None, "ssm_inner"), scale=0.5)
            pb.const(p, s, "A_log", np.broadcast_to(
                np.log(np.arange(1, Hs + 1, dtype=np.float32)), (L, Hs)).copy(),
                (None, None))
            pb.zeros(p, s, "D", (L, Hs), (None, None))
            pb.zeros(p, s, "dt_bias", (L, Hs), (None, None))
            pb.ones(p, s, "ssm_norm", (L, di), (None, "ssm_inner"))
            pb.normal(p, s, "ssm_out", (L, di, d), (None, "ssm_inner", "embed"))
        if cfg.family == "hybrid":
            pb.ones(p, s, "mix_attn", (L, d), (None, "embed"))
            pb.ones(p, s, "mix_ssm", (L, d), (None, "embed"))
        if cfg.family == "moe":
            pb.ones(p, s, "ln_mlp", (L, d), (None, "embed"))
            moe_lib.init_moe(pb, p, s, "moe_", cfg)
        elif cfg.d_ff:
            pb.ones(p, s, "ln_mlp", (L, d), (None, "embed"))
            pb.normal(p, s, "w_gate", (L, d, cfg.d_ff), (None, "embed", "ff"))
            pb.normal(p, s, "w_in", (L, d, cfg.d_ff), (None, "embed", "ff"))
            pb.normal(p, s, "w_out", (L, cfg.d_ff, d), (None, "ff", "embed"))
        return p, s

    # ------------------------------------------------------------------
    # forward building blocks (single layer, full sequence)
    # ------------------------------------------------------------------

    def _attn_full(self, lp, x, positions, *, causal=True, memory=None,
                   prefix=""):
        cfg = self.cfg
        B, S, d = x.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        src = x if memory is None else memory
        q = x @ lp[prefix + "wq"]
        k = src @ lp[prefix + "wk"]
        v = src @ lp[prefix + "wv"]
        if cfg.qkv_bias and not prefix:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        q = q.reshape(B, S, H, hd)
        k = k.reshape(B, src.shape[1], KV, hd)
        v = v.reshape(B, src.shape[1], KV, hd)
        if cfg.qk_norm and not prefix:
            q = head_rms_norm(q, lp["q_norm"])
            k = head_rms_norm(k, lp["k_norm"])
        if memory is None:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        q = shard(q, "batch", "seq", "heads", "head_dim")
        k = shard(k, "batch", "seq", "kv_heads", "head_dim")
        # checkpoint: never save per-KV-block score matrices for backward
        # (the outer remat "dots" policy would otherwise keep every block's
        # (B,H,Sq,blk) f32 scores — recompute instead, flash-bwd style)
        flash = jax.checkpoint(functools.partial(
            attn_lib.flash_attention, causal=causal and memory is None,
            window=cfg.swa_window if memory is None else 0,
            banded_window=cfg.banded_attention))
        out = flash(
            q, k, v,
            q_positions=None if memory is None else positions,
            kv_positions=None if memory is None else
            jnp.arange(src.shape[1], dtype=jnp.int32))
        out = out.reshape(B, S, H * hd)
        return out @ lp[prefix + "wo"], (k, v)

    def _ssm_full(self, lp, u, h0=None, conv_cache=None):
        cfg = self.cfg
        B, S, d = u.shape
        di, N, Hs, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
        if cfg.ssm_split_proj:
            z = u @ lp["ssm_wz"]
            xin = u @ lp["ssm_wx"]
            bc = u @ lp["ssm_wbc"]
            dt = u @ lp["ssm_wdt"]
            cx, cbc = (None, None) if conv_cache is None else conv_cache
            xin, conv_x = ssm_lib.causal_conv(xin, lp["conv_x_w"], cx)
            bc, conv_b = ssm_lib.causal_conv(bc, lp["conv_bc_w"], cbc)
            xs = jax.nn.silu(xin)
            bc = jax.nn.silu(bc)
            Bm, Cm = jnp.split(bc, [N], axis=-1)
            conv_new = (conv_x, conv_b)
        else:
            proj = u @ lp["ssm_in"]
            z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
            xbc, conv_new = ssm_lib.causal_conv(xbc, lp["conv_w"], conv_cache)
            xbc = jax.nn.silu(xbc)
            xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
        xs = shard(xs.reshape(B, S, Hs, cfg.ssm_head_dim),
                   "batch", "seq", None, None)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))
        y, h_last = ssm_lib.ssd_chunked(xs, dt, A, Bm, Cm, h0=h0)
        y = y + xs * lp["D"].astype(y.dtype)[None, None, :, None]
        y = y.reshape(B, S, di)
        y = rms_norm(y, lp["ssm_norm"]) * jax.nn.silu(z)
        return y @ lp["ssm_out"], (h_last, conv_new)

    def _mlp(self, lp, x, dropless: bool = False):
        cfg = self.cfg
        if cfg.family == "moe":
            return moe_lib.moe_ffn(x, lp["moe_router"], lp["moe_gate"],
                                   lp["moe_in"], lp["moe_out"],
                                   top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   dropless=dropless,
                                   groups=0 if dropless else
                                   cfg.moe_group_dispatch)
        return swiglu(x, lp["w_gate"], lp["w_in"], lp["w_out"],
                      shard_fn=lambda h: shard(h, "batch", "seq", "ff"))

    def _layer(self, lp, x, positions, memory=None):
        """One decoder layer, full-sequence.  Returns (x, aux) where aux
        carries whatever the serving cache needs: {"kv": (k, v)} and/or
        {"ssm": (h_last, conv_tail)} (stacked over layers by the scan)."""
        cfg = self.cfg
        aux = {}
        if cfg.family == "ssm":
            y, aux["ssm"] = self._ssm_full(lp, rms_norm(x, lp["ln_ssm"]))
            x = x + y
        elif cfg.family == "hybrid":
            u = rms_norm(x, lp["ln_attn"])
            a_out, aux["kv"] = self._attn_full(lp, u, positions)
            s_out, aux["ssm"] = self._ssm_full(lp, u)
            ones_d = jnp.ones_like(lp["ln_attn"])
            fused = 0.5 * (lp["mix_attn"] * rms_norm(a_out, ones_d)
                           + lp["mix_ssm"] * rms_norm(s_out, ones_d))
            x = x + fused
            x = x + self._mlp(lp, rms_norm(x, lp["ln_mlp"]))
        else:
            a_out, aux["kv"] = self._attn_full(lp, rms_norm(x, lp["ln_attn"]),
                                               positions)
            x = x + a_out
            if memory is not None:
                c_out, _ = self._attn_full(lp, rms_norm(x, lp["ln_cross"]),
                                           positions, memory=memory,
                                           prefix="c")
                x = x + c_out
            x = x + self._mlp(lp, rms_norm(x, lp["ln_mlp"]))
        return shard(x, "batch", "seq", "embed"), aux

    # ------------------------------------------------------------------
    # full-sequence forward (train / prefill)
    # ------------------------------------------------------------------

    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.meta_tokens:
            B = tokens.shape[0]
            meta = jnp.broadcast_to(params["meta"][None],
                                    (B,) + params["meta"].shape)
            x = jnp.concatenate([meta, x.astype(meta.dtype)], axis=1)
        return shard(x, "batch", "seq", "embed")

    def _stack(self, layer_params, x, positions, memory=None,
               collect_aux: bool = False):
        def body(xx, lp):
            out, aux = self._layer(lp, xx, positions, memory=memory)
            return out, (aux if collect_aux else None)

        body = _remat(body, self.cfg.remat)
        x, auxes = _scan(body, x, layer_params)
        return x, auxes

    def _encode(self, params, frames):
        """Encoder stack over stub frame embeddings (B, F, d)."""
        x = shard(frames.astype(self.param_dtype), "batch", "seq", "embed")
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(xx, lp):
            a, _ = self._attn_full(lp, rms_norm(xx, lp["ln_attn"]), positions,
                                   causal=False)
            xx = xx + a
            xx = xx + self._mlp(lp, rms_norm(xx, lp["ln_mlp"]))
            return shard(xx, "batch", "seq", "embed"), None

        x, _ = _scan(_remat(body, self.cfg.remat), x,
                     params["enc_layers"])
        return rms_norm(x, params["enc_final_norm"])

    def logits(self, params, x):
        x = rms_norm(x, params["final_norm"])
        out = x @ params["embed"].T  # tied embeddings
        if self.cfg.padded_vocab > self.cfg.vocab:  # mask padding columns
            cols = jnp.arange(self.cfg.padded_vocab)
            out = jnp.where(cols < self.cfg.vocab, out, -1e30)
        return shard(out, "batch", "seq", "vocab")

    def forward(self, params, tokens, frames=None):
        """Full forward -> logits (B, S(+meta), V)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        memory = self._encode(params, frames) if cfg.is_encdec else None
        x, _ = self._stack(params["layers"], x, positions, memory=memory)
        return self.logits(params, x)

    def loss(self, params, batch):
        """Next-token CE.  batch: {tokens, (frames)}; labels are shifted
        tokens; hymba meta-token positions are dropped before the shift."""
        tokens = batch["tokens"]
        logits = self.forward(params, tokens, frames=batch.get("frames"))
        if self.cfg.meta_tokens:
            logits = logits[:, self.cfg.meta_tokens:]
        labels = tokens[:, 1:]
        return cross_entropy(logits[:, :-1], labels)

    # ------------------------------------------------------------------
    # serving: cache init / prefill / decode_step
    # ------------------------------------------------------------------

    def cache_width(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0
        width = seq_len if not cfg.swa_window else min(cfg.swa_window, seq_len)
        return width

    def init_cache(self, batch: int, seq_len: int):
        """Zero cache pytree (shapes only matter for the dry-run)."""
        cfg = self.cfg
        L = cfg.n_layers
        W = self.cache_width(seq_len)
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        kv_dt = jnp.int8 if self.kv_cache_dtype == "int8" else self.param_dtype
        cache = {"pos": jnp.zeros((), jnp.int32)}
        if W:
            cache["k"] = jnp.zeros((L, batch, W, KV, hd), kv_dt)
            cache["v"] = jnp.zeros((L, batch, W, KV, hd), kv_dt)
            cache["positions"] = jnp.full((batch, W), -1, jnp.int32)
            if self.kv_cache_dtype == "int8":
                cache["k_scale"] = jnp.zeros((L, batch, W, KV, 1), jnp.float32)
                cache["v_scale"] = jnp.zeros((L, batch, W, KV, 1), jnp.float32)
        if cfg.ssm_state:
            cache["ssm_h"] = jnp.zeros(
                (L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32)
            if cfg.ssm_split_proj:
                cache["conv_x"] = jnp.zeros(
                    (L, batch, cfg.ssm_conv - 1, cfg.d_inner),
                    self.param_dtype)
                cache["conv_bc"] = jnp.zeros(
                    (L, batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                    self.param_dtype)
            else:
                cache["conv"] = jnp.zeros(
                    (L, batch, cfg.ssm_conv - 1,
                     cfg.d_inner + 2 * cfg.ssm_state), self.param_dtype)
        if cfg.is_encdec:
            F = cfg.enc_frames
            cache["cross_k"] = jnp.zeros((L, batch, F, KV, hd), self.param_dtype)
            cache["cross_v"] = jnp.zeros((L, batch, F, KV, hd), self.param_dtype)
        return cache

    def cache_specs(self):
        """Logical axes per cache leaf (mirrors init_cache)."""
        specs = {"pos": ()}
        cfg = self.cfg
        if self.cache_width(1 << 30):
            specs.update(k=(None, "batch", "kv_seq", "kv_heads", "head_dim"),
                         v=(None, "batch", "kv_seq", "kv_heads", "head_dim"),
                         positions=("batch", "kv_seq"))
            if self.kv_cache_dtype == "int8":
                specs.update(
                    k_scale=(None, "batch", "kv_seq", "kv_heads", None),
                    v_scale=(None, "batch", "kv_seq", "kv_heads", None))
        if cfg.ssm_state:
            specs.update(ssm_h=(None, "batch", None, "ssm_inner", None))
            if cfg.ssm_split_proj:
                specs.update(conv_x=(None, "batch", None, "ssm_inner"),
                             conv_bc=(None, "batch", None, None))
            else:
                specs.update(conv=(None, "batch", None, "ssm_inner"))
        if cfg.is_encdec:
            specs.update(cross_k=(None, "batch", "frames", "kv_heads", "head_dim"),
                         cross_v=(None, "batch", "frames", "kv_heads", "head_dim"))
        return specs

    def _quant(self, x):
        if self.kv_cache_dtype != "int8":
            return x.astype(self.param_dtype), None
        scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 127.0 + 1e-8
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return q.astype(jnp.int8), scale

    def _dequant(self, q, scale):
        if scale is None:
            return q
        return q.astype(jnp.float32) * scale

    def decode_step(self, params, cache, tokens):
        """One token for every sequence.  tokens: (B, 1) -> logits (B, V)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0)  # (B, 1, d)
        x = shard(x, "batch", None, "embed")
        pos = cache["pos"]
        W = cache["k"].shape[2] if "k" in cache else 0
        write_idx = (pos % W) if (cfg.swa_window and W) else pos
        q_position = jnp.full((B,), pos, jnp.int32)
        new_positions = cache.get("positions")
        if new_positions is not None:
            new_positions = new_positions.at[:, write_idx].set(pos)

        def body(xx, per_layer):
            lp, ck = per_layer
            out_ck = dict(ck)
            if cfg.family in ("dense", "vlm", "audio", "moe", "hybrid", "encdec"):
                u = rms_norm(xx, lp["ln_attn"])
                H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
                q = (u @ lp["wq"]).reshape(B, 1, H, hd)
                k = (u @ lp["wk"]).reshape(B, 1, KV, hd)
                v = (u @ lp["wv"]).reshape(B, 1, KV, hd)
                if cfg.qkv_bias:
                    q = q + lp["bq"].reshape(1, 1, H, hd)
                    k = k + lp["bk"].reshape(1, 1, KV, hd)
                    v = v + lp["bv"].reshape(1, 1, KV, hd)
                if cfg.qk_norm:
                    q = head_rms_norm(q, lp["q_norm"])
                    k = head_rms_norm(k, lp["k_norm"])
                q = rope(q, q_position[:, None], cfg.rope_theta)
                k = rope(k, q_position[:, None], cfg.rope_theta)
                kq, ks = self._quant(k[:, 0])
                vq, vs = self._quant(v[:, 0])
                ck_k = ck["k"].at[:, write_idx].set(kq)
                ck_v = ck["v"].at[:, write_idx].set(vq)
                out_ck["k"], out_ck["v"] = ck_k, ck_v
                ck_ks = ck_vs = None
                if self.kv_cache_dtype == "int8":
                    ck_ks = ck["k_scale"].at[:, write_idx].set(ks)
                    ck_vs = ck["v_scale"].at[:, write_idx].set(vs)
                    out_ck["k_scale"], out_ck["v_scale"] = ck_ks, ck_vs
                a = attn_lib.decode_attention(q, ck_k, ck_v,
                                              new_positions, q_position,
                                              k_scale=ck_ks, v_scale=ck_vs)
                a_out = a.reshape(B, 1, H * hd) @ lp["wo"]
                if cfg.family == "hybrid":
                    s_out, (h_new, conv_new) = self._ssm_decode(lp, u, ck)
                    out_ck["ssm_h"] = h_new
                    self._store_conv(out_ck, conv_new)
                    fused = 0.5 * (lp["mix_attn"] * rms_norm(a_out, jnp.ones_like(lp["ln_attn"]))
                                   + lp["mix_ssm"] * rms_norm(s_out, jnp.ones_like(lp["ln_ssm"])))
                    xx = xx + fused
                else:
                    xx = xx + a_out
                if cfg.is_encdec:
                    u2 = rms_norm(xx, lp["ln_cross"])
                    qc = (u2 @ lp["cwq"]).reshape(B, 1, H, hd)
                    mem_pos = jnp.arange(ck["cross_k"].shape[1], dtype=jnp.int32)
                    c = attn_lib.decode_attention(
                        qc, ck["cross_k"], ck["cross_v"],
                        jnp.broadcast_to(mem_pos, (B, mem_pos.shape[0])),
                        jnp.full((B,), 1 << 30, jnp.int32))
                    xx = xx + c.reshape(B, 1, H * hd) @ lp["cwo"]
                xx = xx + self._mlp(lp, rms_norm(xx, lp["ln_mlp"]),
                                    dropless=True)
            else:  # pure ssm
                u = rms_norm(xx, lp["ln_ssm"])
                y, (h_new, conv_new) = self._ssm_decode(lp, u, ck)
                out_ck["ssm_h"] = h_new
                self._store_conv(out_ck, conv_new)
                xx = xx + y
            return xx, out_ck

        layer_cache = {k: v for k, v in cache.items()
                       if k not in ("pos", "positions")}
        x, new_layer_cache = _scan(body, x,
                                   (params["layers"], layer_cache))
        logits = self.logits(params, x)[:, 0]
        new_cache = dict(new_layer_cache)
        new_cache["pos"] = pos + 1
        if new_positions is not None:
            new_cache["positions"] = new_positions
        return logits, new_cache

    def _ssm_decode(self, lp, u, ck):
        cfg = self.cfg
        B = u.shape[0]
        di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        if cfg.ssm_split_proj:
            z = u[:, 0] @ lp["ssm_wz"]
            xin = u[:, 0] @ lp["ssm_wx"]
            bc = u[:, 0] @ lp["ssm_wbc"]
            dt = u[:, 0] @ lp["ssm_wdt"]
            xin, conv_x = ssm_lib.causal_conv(xin[:, None], lp["conv_x_w"],
                                              cache=ck["conv_x"])
            bc, conv_b = ssm_lib.causal_conv(bc[:, None], lp["conv_bc_w"],
                                             cache=ck["conv_bc"])
            xs = jax.nn.silu(xin[:, 0])
            bc = jax.nn.silu(bc[:, 0])
            Bm, Cm = jnp.split(bc, [N], axis=-1)
            conv_new = (conv_x, conv_b)
        else:
            proj = u[:, 0] @ lp["ssm_in"]
            z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
            xbc, conv_new = ssm_lib.causal_conv(xbc[:, None], lp["conv_w"],
                                                cache=ck["conv"])
            xbc = jax.nn.silu(xbc[:, 0])
            xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
        xs = xs.reshape(B, Hs, cfg.ssm_head_dim)
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + lp["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))
        y, h_new = ssm_lib.ssd_decode_step(xs, dt, A, Bm, Cm, ck["ssm_h"])
        y = y + xs * lp["D"].astype(y.dtype)[None, :, None]
        y = y.reshape(B, di)
        y = rms_norm(y, lp["ssm_norm"]) * jax.nn.silu(z)
        return (y @ lp["ssm_out"])[:, None], (h_new, conv_new)


    def _store_conv(self, cache: dict, conv_new) -> None:
        if self.cfg.ssm_split_proj:
            cache["conv_x"], cache["conv_bc"] = conv_new
        else:
            cache["conv"] = conv_new

    def prefill(self, params, tokens, frames=None, cache_len: int = 0):
        """Full-sequence forward that also builds the decode cache.

        ``cache_len`` reserves room for subsequent decode steps (defaults to
        ``cfg.max_cache``); sliding-window caches are ring-aligned so that
        position ``p`` lives at slot ``p % W`` — the invariant decode_step
        writes with."""
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(params, tokens)
        S_tot = x.shape[1]
        positions = jnp.arange(S_tot, dtype=jnp.int32)
        memory = self._encode(params, frames) if cfg.is_encdec else None
        x, auxes = self._stack(params["layers"], x, positions, memory=memory,
                               collect_aux=True)
        cache_len = cache_len or max(cfg.max_cache, S_tot)
        cache = self.init_cache(B, cache_len)
        if "kv" in auxes:
            k_all, v_all = auxes["kv"]  # (L, B, S_tot, KV, hd)
            W = cache["k"].shape[2]
            if cfg.swa_window and W < S_tot:
                # last W entries, ring-aligned: slot(p) == p % W
                kw, vw = k_all[:, :, -W:], v_all[:, :, -W:]
                shift = S_tot % W
                kw = jnp.roll(kw, shift, axis=2)
                vw = jnp.roll(vw, shift, axis=2)
                pw = jnp.roll(positions[-W:], shift)
                kq, ks = self._quant(kw)
                vq, vs = self._quant(vw)
                cache["k"], cache["v"] = kq, vq
                if ks is not None:
                    cache["k_scale"], cache["v_scale"] = ks, vs
                cache["positions"] = jnp.broadcast_to(pw[None], (B, W))
            else:
                kq, ks = self._quant(k_all)
                vq, vs = self._quant(v_all)
                cache["k"] = cache["k"].at[:, :, :S_tot].set(kq)
                cache["v"] = cache["v"].at[:, :, :S_tot].set(vq)
                if ks is not None:
                    cache["k_scale"] = cache["k_scale"].at[:, :, :S_tot].set(ks)
                    cache["v_scale"] = cache["v_scale"].at[:, :, :S_tot].set(vs)
                cache["positions"] = cache["positions"].at[:, :S_tot].set(
                    jnp.broadcast_to(positions[None], (B, S_tot)))
        if "ssm" in auxes:
            h_last, conv_tail = auxes["ssm"]   # (L,B,H,P,N), (L,B,K-1,Cch)
            cache["ssm_h"] = h_last
            self._store_conv(cache, jax.tree.map(
                lambda c: c.astype(self.param_dtype), conv_tail))
        if cfg.is_encdec:
            L = cfg.n_layers
            KV, hd = cfg.n_kv_heads, cfg.head_dim

            def cross_kv(carry, lp):
                k = (memory @ lp["cwk"]).reshape(B, -1, KV, hd)
                v = (memory @ lp["cwv"]).reshape(B, -1, KV, hd)
                return carry, (k, v)

            _, (ck, cv) = _scan(cross_kv, 0, params["layers"])
            cache["cross_k"], cache["cross_v"] = ck, cv
        cache["pos"] = jnp.asarray(S_tot, jnp.int32)
        logits = self.logits(params, x[:, -1:])[:, 0]
        return logits, cache
