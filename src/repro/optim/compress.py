"""Distributed-optimization tricks: gradient compression.

Two layers, matching DESIGN.md §3.2:

1. **Cross-pod int8 all-gather with error feedback** (device side).
   Under the multi-pod mesh the DP gradient reduction crosses the slow
   inter-pod links.  ``crosspod_compressed_grads`` runs the model math under
   GSPMD (``shard_map`` manual only over the "pod" axis, auto over
   data/model): each pod's locally-reduced gradient block is int8
   block-quantized (+ error feedback residual carried in the optimizer
   state), all-gathered over "pod" as int8 — 4x fewer inter-pod bytes than
   an fp32 ring all-reduce — then dequantized and averaged.  The quantizer
   is unbiased within a block up to rounding; EF makes the scheme convergent
   (Karimireddy et al.).

2. **Recoil-coded residual streams** (host side, repro.checkpoint +
   examples/checkpoint_distribution.py): int8 payloads are entropy-coded
   with the paper's codec; heterogeneous subscribers thin the split metadata
   to their own parallelism — the paper's content-delivery story applied to
   parameter/gradient distribution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


def quantize_int8(g: jax.Array, block: int = BLOCK):
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, size: int):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_decompress(g: jax.Array, ef: jax.Array, axis_name: str | None):
    """One gradient leaf: add EF, quantize, (all-gather over pods), average,
    return (g_hat, new_ef).  With axis_name=None this is the single-pod
    identity-communication path (still quantizes, for EF parity in tests).

    EF residuals are per-pod state; under shard_map they carry a leading
    pod-block axis of size 1 (sharded P("pod", ...)), detected by ndim."""
    lead = ef.ndim == g.ndim + 1
    if lead:
        ef = ef[0]
    size = int(np.prod(g.shape))
    gq_in = g.astype(jnp.float32) + ef
    q, scale = quantize_int8(gq_in)
    local_hat = dequantize_int8(q, scale, g.shape, size)
    new_ef = gq_in - local_hat
    if lead:
        new_ef = new_ef[None]
    if axis_name is None:
        return local_hat.astype(g.dtype), new_ef
    # int8 payload crosses the pod links; dequantize+mean locally.
    q_all = jax.lax.all_gather(q, axis_name)          # (pods, nb, B) int8
    s_all = jax.lax.all_gather(scale, axis_name)      # (pods, nb, 1) f32
    n_pods = q_all.shape[0]
    acc = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0) / n_pods
    g_hat = acc.reshape(-1)[:size].reshape(g.shape)
    return g_hat.astype(g.dtype), new_ef


def init_error_feedback(params, n_pods: int = 0):
    """n_pods > 0 adds the leading per-pod axis (shard_map manual mode)."""
    lead = (n_pods,) if n_pods else ()
    return jax.tree.map(
        lambda p: jnp.zeros(lead + p.shape, jnp.float32), params)


def compress_tree(grads, ef_tree, axis_name: str | None):
    """Apply cross-pod compression to every gradient leaf."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_tree)
    out = [compress_decompress(g, e, axis_name)
           for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def compressed_bytes_ratio(params) -> float:
    """Napkin: payload bytes (int8 + fp32 scale per block) vs fp32."""
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    comp = n + (n // BLOCK + 1) * 4
    return comp / (4 * n)
