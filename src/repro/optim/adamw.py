"""AdamW with fp32 moments, ZeRO-1-shardable.

Moments are plain pytrees mirroring params; their shardings are derived by
:func:`moment_specs` — the param's own logical axes plus a "moments" axis
(-> the data mesh axis) on the largest still-unsharded divisible dim, which
is exactly ZeRO-1: optimizer state sharded over data, params replicated over
data.  The gathered moments never materialize: the update runs sharded and
GSPMD keeps every elementwise op local.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_moments(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def apply_adamw(params, grads, opt_state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, {"grad_norm": gnorm}


def moment_specs(param_specs: Any, params_shapes: Any, data_axis_size: int,
                 rules=None):
    """ZeRO-1 sharding: add the "moments" logical axis on the largest dim
    that *resolves* to replicated (given the active rules) and is divisible,
    so moments shard over data on top of the param's own model sharding."""
    def one(axes, shape):
        axes = tuple(axes)
        resolved = (rules.spec(axes, shape.shape) if rules is not None
                    else tuple(None if a is None else a for a in axes))
        best, best_size = None, 0
        for i, (a, s) in enumerate(zip(tuple(resolved), shape.shape)):
            if a is None and s % data_axis_size == 0 and s > best_size:
                best, best_size = i, s
        if best is None:
            return axes
        return axes[:best] + ("moments",) + axes[best + 1:]

    return jax.tree.map(one, param_specs, params_shapes,
                        is_leaf=lambda x: isinstance(x, tuple))
